"""Basis residency (DESIGN.md §6): conversion elision is REAL, counted, and
numerically free.

The conversion counters in `repro.core.rep` tick every time a
`sh_to_fourier` / `fourier_to_sh` code path runs (once per eager call, once
per jit trace).  These tests pin the acceptance criteria: every chained
workload — many-body trees, selfmix (shared operand), conv filter stacks —
eliminates at least one interior conversion pair versus the looped
per-product path, and the resident results match the looped ones.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, rep
from repro.core.cg import gaunt_einsum_reference
from repro.core.conv import EquivariantConv
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_gaunt_product, manybody_selfmix
from repro.core.rep import Rep


def _rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype)


def _count(fn):
    """Run ``fn`` and return (s2f, f2s) conversion deltas.

    `conversion_stats(fresh=True)` scopes the counters to the block
    (snapshot/restore — robust to other tests' leftovers) and drops the
    cached `ChainPlan.apply_jit` dispatches so every counted chain traces
    fresh (warm jit caches tick zero)."""
    with rep.conversion_stats(fresh=True) as c:
        fn()
    return c["sh_to_fourier"], c["fourier_to_sh"]


# --------------------------------------------------------------------------
# counters: chains beat the looped path by >= 1 interior pair
# --------------------------------------------------------------------------


@pytest.mark.parametrize("conversion", ["dense", "half"])
def test_manybody_chain_eliminates_interior_pairs(conversion):
    nu, L = 3, 2
    xs = [_rand((4, num_coeffs(L)), i) for i in range(nu)]

    def looped():
        acc, La = xs[0], L
        for x in xs[1:]:
            acc = engine.plan(La, L, La + L, backend="fft").apply(acc, x)
            La += L

    def chained():
        engine.plan_chain((L,) * nu, conversion=conversion).apply(xs)

    s2f_loop, f2s_loop = _count(looped)
    s2f_chain, f2s_chain = _count(chained)
    assert (s2f_loop, f2s_loop) == (2 * (nu - 1), nu - 1)
    assert (s2f_chain, f2s_chain) == (nu, 1)
    # >= 1 interior fourier_to_sh . sh_to_fourier pair eliminated
    pairs_eliminated = min(s2f_loop - s2f_chain, f2s_loop - f2s_chain)
    assert pairs_eliminated >= 1
    cp = engine.plan_chain((L,) * nu, conversion=conversion)
    assert cp.interior_pairs_eliminated == nu - 2 >= 1


def test_selfmix_shared_operand_single_conversion():
    """MACE-style B_nu = A (x) A (x) A with per-operand weights: ONE
    degree-resolved conversion serves all nu operands."""
    L, nu = 2, 3
    x = _rand((3, num_coeffs(L)), 10)
    ws = [_rand((3, L + 1), 20 + i) for i in range(nu)]
    s2f, f2s = _count(lambda: manybody_selfmix(x, L, nu, Lout=L, weights=ws))
    assert (s2f, f2s) == (1, 1)
    # looped path would pay 2(nu-1) + (nu-1) = 3(nu-1) conversions
    cc = engine.plan_chain((L,) * nu, L).conversion_counts(n_unique=1)
    assert cc["looped"] == (2 * (nu - 1), nu - 1)
    assert cc["chain"] == (1, 1)


def test_conv_filter_rep_converts_once_across_layers():
    """A layer stack over fixed edge geometry: the filter converts once."""
    L, n_layers = 2, 3
    conv = EquivariantConv(L, L, L, method="general")
    x = _rand((8, num_coeffs(L)), 30)
    v = np.random.default_rng(31).normal(size=(8, 3))
    r = jnp.asarray(v / np.linalg.norm(v, axis=-1, keepdims=True), jnp.float32)

    def per_layer():
        # the eager per-product path (conv.plan is the conv_filter plan;
        # the batched route jit-caches its bucket, hiding executions from
        # the trace-time counters, so count the raw applies)
        for _ in range(n_layers):
            conv.plan.apply(x, r)

    def resident():
        filt = conv.filter_rep(r)
        for _ in range(n_layers):
            conv(x, filt)

    s2f_loop, f2s_loop = _count(per_layer)
    s2f_res, f2s_res = _count(resident)
    assert s2f_loop == 2 * n_layers and f2s_loop == n_layers
    # 1 filter conversion + n_layers x-conversions; projections unchanged
    assert s2f_res == n_layers + 1 and f2s_res == n_layers
    assert s2f_loop - s2f_res == n_layers - 1 >= 1
    # and the outputs agree
    filt = conv.filter_rep(r)
    np.testing.assert_allclose(np.asarray(conv(x, filt)),
                               np.asarray(conv(x, r)), atol=1e-4)


def test_boundary_plan_resident_output_feeds_next_product():
    """A resident output Rep enters the next chain with no round trip."""
    L = 2
    x1, x2, x3 = (_rand((4, num_coeffs(L)), 40 + i) for i in range(3))
    p = engine.plan(L, L, 2 * L, backend="fft",
                    options={"boundary": ("sh", "sh", "fourier")})

    def resident():
        mid = p.apply(x1, x2)           # Rep, stays in the Fourier basis
        engine.plan_chain((2 * L, L), Lout=L).apply([mid, x3])

    s2f, f2s = _count(resident)
    assert (s2f, f2s) == (3, 1)  # looped would be (4, 2)
    mid = p.apply(x1, x2)
    got = engine.plan_chain((2 * L, L), Lout=L).apply([mid, x3])
    acc = gaunt_einsum_reference(x1, x2, L, L)
    acc = gaunt_einsum_reference(acc, x3, 2 * L, L, L)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=2e-3)


# --------------------------------------------------------------------------
# models: the resident path is numerically the same as the legacy path
# --------------------------------------------------------------------------


def test_segnn_resident_matches_nonresident():
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import SegnnNBody

    cfg = EquivariantConfig(name="t", kind="segnn", L=1, L_edge=1, channels=4,
                            n_layers=2)
    n = 5
    rng = np.random.default_rng(50)
    charge = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    vel = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    model_on = SegnnNBody(cfg)
    params = model_on.init(jax.random.PRNGKey(0))
    out_on = model_on.forward(params, charge, pos, vel)
    model_off = SegnnNBody(dataclasses.replace(cfg, fourier_resident=False))
    out_off = model_off.forward(params, charge, pos, vel)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               atol=1e-4)

    # and the resident forward converts the edge filter ONCE for the whole
    # stack: n_layers x-side conversions + 1 filter conversion.  (The legacy
    # path converts the filter inside every layer's product — its per-product
    # cost is pinned by the plan-level counter tests above; its model-level
    # count is invisible here because plan_batch jit-caches its buckets.)
    s2f_on, f2s_on = _count(lambda: model_on.forward(params, charge, pos, vel))
    assert s2f_on == cfg.n_layers + 1
    assert f2s_on == cfg.n_layers


def test_selfmix_layer_resident_matches_batched():
    from repro.models.equivariant import SelfmixLayer

    L, C = 2, 3
    x = _rand((6, C, num_coeffs(L)), 60)
    layer_on = SelfmixLayer(L=L, channels=C, tp_impl="gaunt")
    params = layer_on.init(jax.random.PRNGKey(1))
    params = jax.tree.map(
        lambda a: a * (1 + 0.1 * jnp.arange(a.size).reshape(a.shape)), params)
    layer_off = SelfmixLayer(L=L, channels=C, tp_impl="gaunt", resident=False)
    out_on = layer_on(params, x)
    out_off = layer_off(params, x)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               atol=1e-4)
    s2f_on, _ = _count(lambda: layer_on(params, x))
    assert s2f_on == 1  # shared operand: one degree-resolved conversion


def test_mace_resident_matches_nonresident_general_conv():
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import MaceGaunt

    cfg = EquivariantConfig(name="t", kind="mace", L=1, L_edge=1, channels=4,
                            n_layers=2, nu=3, conv_impl="general")
    n = 4
    rng = np.random.default_rng(70)
    species = jnp.asarray(rng.integers(0, cfg.n_species, size=(n,)))
    pos = jnp.asarray(rng.normal(size=(n, 3)) * 1.5, jnp.float32)
    model_on = MaceGaunt(cfg)
    params = model_on.init(jax.random.PRNGKey(2))
    e_on = model_on.energy(params, species, pos)
    model_off = MaceGaunt(dataclasses.replace(cfg, fourier_resident=False))
    e_off = model_off.energy(params, species, pos)
    np.testing.assert_allclose(float(e_on), float(e_off), rtol=1e-4, atol=1e-4)


# --------------------------------------------------------------------------
# grid-resident gates (DESIGN.md §6.5): the nonlinearity is chain-interior
# --------------------------------------------------------------------------


def _gate_params(C, seed):
    rng = np.random.default_rng(seed)
    return {"w1": jnp.asarray(rng.normal(size=(C, 16)) * 0.3, jnp.float32),
            "w2": jnp.asarray(rng.normal(size=(16, C)) * 0.3, jnp.float32)}


def test_grid_gate_region_single_entry_exit_pair():
    """THE elision proof: a whole TP -> gate -> selfmix layer plans as ONE
    grid-resident region.  The gated TP exits resident (the gate rides the
    grid), the selfmix re-enters for free, so the region pays one entry
    group + one exit — the SH-side gate forces a full exit -> gate ->
    re-entry in the middle and pays >= 2 conversion pairs."""
    L, B, C = 1, 4, 3
    Ltot = 2 * L
    x1 = _rand((B, C, num_coeffs(L)), 500)
    x2 = _rand((B, C, num_coeffs(L)), 501)
    gp = _gate_params(C, 502)
    tp_g = engine.plan_chain((L, L), Ltot, backend="tree", gate=True)
    tp = engine.plan_chain((L, L), Ltot, backend="tree")
    mix = engine.plan_chain((Ltot, Ltot), Ltot, backend="tree")

    def grid_region():
        mid = tp_g.apply([x1, x2], out_basis="fourier", gate_params=gp)
        mix.apply([mid, mid])

    def sh_region():
        y = engine._gate_sh(gp, tp.apply([x1, x2]))
        mix.apply([y, y])

    s2f_grid, f2s_grid = _count(grid_region)
    s2f_sh, f2s_sh = _count(sh_region)
    # grid: 2 operand entries + 1 region exit; the gate adds NOTHING
    assert (s2f_grid, f2s_grid) == (2, 1)
    # SH gate: TP pays (2, 1), then the gated product re-enters the selfmix
    # (one shared-operand conversion) — a full extra exit/entry pair
    assert (s2f_sh, f2s_sh) == (3, 2)
    pairs_eliminated = min(s2f_sh - s2f_grid, f2s_sh - f2s_grid)
    assert pairs_eliminated >= 1
    # and the two regions compute the same thing
    mid = tp_g.apply([x1, x2], out_basis="fourier", gate_params=gp)
    got = mix.apply([mid, mid])
    y = engine._gate_sh(gp, tp.apply([x1, x2]))
    want = mix.apply([y, y])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_selfmix_gate_params_matches_gate_apply():
    """manybody_selfmix(gate_params=...) == the models' gate applied to the
    ungated self-product — the fused stage is exact, not approximate."""
    from repro.models.equivariant import gate_apply

    L, nu, B, C = 2, 3, 4, 3
    x = _rand((B, C, num_coeffs(L)), 510)
    gp = _gate_params(C, 511)
    want = gate_apply(gp, manybody_selfmix(x, L, nu, Lout=L), L)
    got = manybody_selfmix(x, L, nu, Lout=L, gate_params=gp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # the gate is a chain-route feature: an explicit backend pins the
    # per-plan route, which rejects it
    with pytest.raises(ValueError, match="chain route"):
        manybody_gaunt_product([x, x], (L, L), Lout=L, backend="fft",
                               gate_params=gp)


def test_mace_grid_gate_one_conversion_pair_per_layer():
    """Acceptance: a MaceGaunt layer with grid_gate='on' executes with
    exactly ONE entry + ONE exit conversion (the gate lives inside the
    selfmix chain's resident region).  With identity mb_mix the reordered
    parameterization coincides with the legacy one, so the outputs match."""
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import MaceGaunt

    cfg = EquivariantConfig(name="t", kind="mace", L=1, L_edge=1, channels=5,
                            n_layers=1, nu=3, conv_impl="escn")
    n = 4
    rng = np.random.default_rng(520)
    species = jnp.asarray(rng.integers(0, cfg.n_species, size=(n,)))
    pos = jnp.asarray(rng.normal(size=(n, 3)) * 1.5, jnp.float32)
    model_on = MaceGaunt(dataclasses.replace(cfg, grid_gate="on"))
    params = model_on.init(jax.random.PRNGKey(3))
    # the jit-cached chain ticks at trace time only, while the per-forward
    # conv re-traces every call (fresh EquivariantConv per features call):
    # first-minus-second isolates the gated many-body region's conversions
    first = _count(lambda: model_on.features(params, species, pos))
    second = _count(lambda: model_on.features(params, species, pos))
    assert (first[0] - second[0], first[1] - second[1]) == (1, 1)
    # and the fused gate adds nothing anywhere else: steady state matches
    # the ungated model's steady state exactly
    model_plain = MaceGaunt(cfg)
    model_plain.features(params, species, pos)  # warm its chain trace
    assert second == _count(lambda: model_plain.features(params, species, pos))
    # identity channel mix makes gate-before-mix == gate-after-mix exactly
    for lp in params["layers"]:
        lp["mb_mix"] = jnp.broadcast_to(
            jnp.eye(cfg.channels), (cfg.L + 1, cfg.channels, cfg.channels))
    out_on = model_on.features(params, species, pos)
    out_off = MaceGaunt(cfg).features(params, species, pos)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               rtol=1e-5, atol=1e-5)


def test_segnn_grid_gate_quad_path_matches_off():
    """SEGNN's post-mix gate has no adjacent chain to fuse into: grid_gate
    ='on' routes it through the S^2 quadrature Rep (exact — the gate is
    affine), ticking one sh_to_quad/quad_to_sh pair per layer."""
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import SegnnNBody

    cfg = EquivariantConfig(name="t", kind="segnn", L=1, L_edge=1, channels=4,
                            n_layers=2)
    n = 5
    rng = np.random.default_rng(530)
    charge = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    vel = jnp.asarray(rng.normal(size=(n, 3)), jnp.float32)
    model_off = SegnnNBody(cfg)
    params = model_off.init(jax.random.PRNGKey(4))
    model_on = SegnnNBody(dataclasses.replace(cfg, grid_gate="on"))
    with rep.conversion_stats(fresh=True) as c:
        out_on = model_on.forward(params, charge, pos, vel)
    assert c["sh_to_quad"] == cfg.n_layers
    assert c["quad_to_sh"] == cfg.n_layers
    out_off = model_off.forward(params, charge, pos, vel)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               rtol=1e-5, atol=1e-5)


def test_resolve_grid_gate_policy():
    from repro.configs.gaunt_ff import EquivariantConfig
    from repro.models.equivariant import _resolve_grid_gate

    cfg = EquivariantConfig(name="t", kind="mace", L=1, channels=4)
    Ls = (1, 1, 1)
    assert _resolve_grid_gate(cfg, Ls, 1) is False
    assert _resolve_grid_gate(
        dataclasses.replace(cfg, grid_gate="on"), Ls, 1) is True
    # 'auto' without measured tuning stays off (no silent timing runs)
    assert _resolve_grid_gate(
        dataclasses.replace(cfg, grid_gate="auto"), Ls, 1) is False
    with pytest.raises(ValueError, match="grid_gate"):
        _resolve_grid_gate(
            dataclasses.replace(cfg, grid_gate="bogus"), Ls, 1)


# --------------------------------------------------------------------------
# Rep semantics
# --------------------------------------------------------------------------


def test_rep_pytree_through_jit_and_resize():
    L = 2
    x = _rand((3, num_coeffs(L)), 80)
    r = Rep.from_sh(x, L).to_fourier("dense")

    @jax.jit
    def f(r):
        return r.resize(L + 2).resize(L).to_sh().data

    np.testing.assert_allclose(np.asarray(f(r)), np.asarray(x), atol=2e-5)


def test_rep_add_and_errors():
    L = 1
    a = Rep.from_sh(_rand((2, 4), 90), L).to_fourier("dense")
    b = Rep.from_sh(_rand((2, 4), 91), L).to_fourier("half")
    s = (a + b).to_sh()
    assert s.L == L
    with pytest.raises(ValueError):
        Rep.from_sh(_rand((2, 4), 92), L).resize(2)
    with pytest.raises(ValueError):
        engine.plan(1, 1, 1, backend="fft",
                    options={"boundary": ("sh", "sh", "fourier")})
    with pytest.raises(ValueError):
        engine.plan(1, 1, 2, backend="dense_einsum",
                    options={"boundary": ("sh", "fourier", "sh")})


def test_chain_rejects_weighted_resident_operand():
    L = 1
    x = _rand((2, 4), 95)
    r = Rep.from_sh(x, L).to_fourier("dense")
    cp = engine.plan_chain((L, L), Lout=L)
    with pytest.raises(ValueError):
        cp.apply([r, x], weights=[_rand((2, 2), 96), None])
