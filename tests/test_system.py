"""End-to-end behaviour tests for the paper's system: the Gaunt Tensor
Product primitive wired through a real training run, the fault-tolerance
path, and the multi-device dry-run contract (on a small host mesh)."""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.data import lj_dataset
from repro.models.equivariant import MaceGaunt
from repro.train import train_loop


def test_force_field_end_to_end_with_restart(tmp_path):
    """Train the paper-side model, stop it mid-run, resume from the
    checkpoint, and verify the final model is E(3)-sound."""
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, L=1, L_edge=1,
                              n_layers=1, nu=2, n_radial=4, hidden=16)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    data = lj_dataset(12, n_atoms=6, n_species=4, seed=0)

    class Batches:
        step = 0

        def state(self):
            return {"step": self.step}

        def restore(self, s):
            self.step = int(s["step"])

        def next_batch(self):
            rng = np.random.default_rng((7, self.step))
            idx = rng.choice(12, 6, replace=False)
            self.step += 1
            return {k: v[idx] for k, v in data.items()}

    def loss_fn(p, batch):
        loss = model.loss(p, batch)
        return loss, {}

    # phase 1: run 8 steps, checkpoint at 4 and 8
    t1 = TrainConfig(lr=2e-3, warmup_steps=2, total_steps=8, checkpoint_every=4,
                     log_every=4, grad_clip=10.0)
    train_loop(loss_fn, params, Batches(), t1, ckpt_dir=str(tmp_path))
    # phase 2 ("restart after preemption"): extend to 14 steps
    t2 = dataclasses.replace(t1, total_steps=14)
    b2 = Batches()
    state, hist = train_loop(loss_fn, params, b2, t2, ckpt_dir=str(tmp_path))
    assert state.step == 14
    assert b2.step == 14  # data pipeline resumed, not replayed
    # E(3) soundness of the final model
    from repro.core.so3 import rotation_matrix_zyz

    R = jnp.asarray(rotation_matrix_zyz(0.4, 1.0, -0.2), jnp.float32)
    s0 = jnp.asarray(data["species"][0])
    p0 = jnp.asarray(data["pos"][0])
    e1 = model.energy(state.params, s0, p0)
    e2 = model.energy(state.params, s0, p0 @ R.T)
    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4, atol=1e-3)


def test_dryrun_tiny_cell_subprocess():
    """The dry-run contract end-to-end (subprocess so the 8-device XLA flag
    does not leak into this process)."""
    code = (
        "import os; os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8';"
        "import repro.launch.dryrun as D;"
        "import repro.launch.mesh as M, jax;"
        "M.make_production_mesh = lambda multi_pod=False: jax.make_mesh("
        "(2,2,2) if multi_pod else (4,2), ('pod','data','model') if multi_pod"
        " else ('data','model'),"
        "**M._axis_type_kwargs(3 if multi_pod else 2));"
        # dryrun binds the name at import — patch its reference too
        "D.make_production_mesh = M.make_production_mesh;"
        "r1 = D.dryrun_cell('qwen2-0.5b','train_4k', False, tiny=True);"
        "r2 = D.dryrun_cell('qwen2-0.5b','decode_32k', True, tiny=True);"
        "assert r1['status']=='ok' and r2['status']=='ok', (r1, r2);"
        "assert r1['cost']['flops_per_device'] > 0;"
        "print('DRYRUN_OK')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        timeout=900,
    )
    assert "DRYRUN_OK" in out.stdout, out.stderr[-2000:]


def test_gaunt_primitive_in_training_matches_cg_class():
    """Sanity-check claim (paper Fig 1e): swapping CG -> Gaunt
    parameterization preserves trainability on the same task/seed."""
    from repro.configs.gaunt_ff import gaunt_segnn_nbody
    from repro.data import nbody_dataset
    from repro.models.equivariant import SegnnNBody

    data = nbody_dataset(6, horizon=150, seed=3)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    finals = {}
    for impl in ("gaunt", "cg"):
        cfg = dataclasses.replace(gaunt_segnn_nbody, tp_impl=impl, channels=8,
                                  n_layers=1, n_radial=4)
        m = SegnnNBody(cfg)
        p = m.init(jax.random.PRNGKey(5))
        g = jax.jit(jax.grad(m.loss))
        for _ in range(5):
            p = jax.tree.map(lambda a, b: a - 1e-2 * b, p, g(p, batch))
        finals[impl] = float(m.loss(p, batch))
    # same accuracy class: within 2x of each other after identical budgets
    ratio = finals["gaunt"] / max(finals["cg"], 1e-9)
    assert 0.5 < ratio < 2.0, finals
