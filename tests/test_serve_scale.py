"""Serve scale-out integration (DESIGN.md §10): bucketed pools serve mixed
workloads with direct-evaluation numerics, async pipelining stages overlap
work while steps are in flight, deadlines hold under the real engine, and —
the ISSUE acceptance proof — per-bucket warmup performs ZERO timing runs on
a warm autotune cache (subprocess-counter-proven)."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.serve.scheduler import REASON_DEADLINE, Scheduler


@pytest.fixture(scope="module")
def small_model():
    cfg = dataclasses.replace(gaunt_mace_ff, channels=8, n_layers=1, L=1,
                              L_edge=1, n_species=4)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _mol(n, seed):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 4, n),
            (rng.normal(size=(n, 3)) * 1.5).astype(np.float32))


def _direct_energy(model, params, r):
    return float(model.energy(params, jnp.asarray(r.species),
                              jnp.asarray(np.asarray(r.pos, np.float32))))


def test_bucketed_mixed_workload_matches_direct(small_model):
    """A mixed small/large workload routed across two buckets completes
    with per-request energies equal to unpadded direct evaluation — bucket
    padding is inert in every bucket, not just the largest."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(4, 2), (10, 2)])
    sizes = [2, 3, 4, 5, 7, 10, 3, 8]
    reqs = [EquivariantRequest(*_mol(n, seed=i), rid=i)
            for i, n in enumerate(sizes)]
    out = eng.run(reqs)
    assert all(r.done and not r.rejected for r in out)
    for r in out:
        e = _direct_energy(model, params, r)
        assert abs(r.energy - e) < 1e-4 * max(1.0, abs(e)), r.rid
    # both buckets actually served
    assert all(p.steps_run > 0 for p in eng.pools)
    s = eng.metrics.summary()
    assert s["completed"] == len(reqs)
    assert 0.0 < s["padding_efficiency"] <= 1.0
    assert s["latency_p50_ms"] <= s["latency_p99_ms"]


def test_bucketed_equals_single_bucket_results(small_model):
    """The bucket ladder changes padding and scheduling, never numbers:
    identical request streams through a bucketed and a single-max_atoms
    engine produce identical energies/forces (same ghost-atom contract)."""
    model, params = small_model

    def serve(buckets):
        reqs = [EquivariantRequest(*_mol(n, seed=i), rid=i)
                for i, n in enumerate([2, 5, 9, 3, 7])]
        EquivariantServeEngine(model, params, n_slots=2, max_atoms=9,
                               buckets=buckets).run(reqs)
        return reqs

    single = serve(None)
    bucketed = serve([(3, 2), (6, 2), (9, 2)])
    for a, b in zip(single, bucketed):
        np.testing.assert_allclose(a.energy, b.energy, rtol=1e-5)
        np.testing.assert_allclose(a.forces, b.forces, rtol=1e-4, atol=1e-6)


def test_relaxation_across_buckets(small_model):
    """Multi-step relaxation holds inside a bucket (staged tensors are
    re-uploaded after each relaxation write, not stale-reused)."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(4, 1), (8, 1)])
    sp, pos0 = _mol(4, 7)
    s = 1e5
    req = EquivariantRequest(species=sp, pos=pos0.copy(), steps=2,
                             step_size=s)
    out = eng.run([req])[0]
    assert out.done
    e0, f0 = model.energy_forces(params, jnp.asarray(sp), jnp.asarray(pos0))
    pos1 = pos0 + s * np.asarray(f0)
    e1, f1 = model.energy_forces(params, jnp.asarray(sp), jnp.asarray(pos1))
    np.testing.assert_allclose(out.pos, pos1, rtol=1e-5, atol=1e-6)
    assert abs(out.energy - float(e1)) < 1e-4 * max(1.0, abs(float(e1)))


def test_repeated_eval_staged_reuse_is_not_stale(small_model):
    """steps>1 with step_size=0 re-evaluates the SAME geometry: the staging
    cache may reuse the uploaded tensors, but every step must produce the
    direct-evaluation energy (reuse is an upload economy, not a result
    cache)."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)])
    sp, pos = _mol(4, 13)
    req = EquivariantRequest(species=sp, pos=pos.copy(), steps=3,
                             step_size=0.0)
    out = eng.run([req])[0]
    assert out.done
    e = _direct_energy(model, params, out)
    assert abs(out.energy - e) < 1e-4 * max(1.0, abs(e))
    assert eng.pools.pools[0].steps_run == 3


def test_overlap_admission_stages_early(small_model):
    """Async pipelining: a request arriving while another bucket's step is
    in flight is admitted AND device-staged inside the overlap window
    (metrics count the early staging), and completes with correct
    numerics."""
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(4, 1), (8, 1)])
    sched = Scheduler(eng)
    big = EquivariantRequest(*_mol(8, seed=1), steps=2, rid=0)
    small = EquivariantRequest(*_mol(3, seed=2), rid=1)
    sched.submit(big)
    calls = {"n": 0}

    def poll():
        # fires once per overlap pass; inject the small arrival only inside
        # a step's overlap window (the scheduler has already admitted `big`)
        calls["n"] += 1
        if calls["n"] == 2:
            sched.submit(small)

    while sched.pump(poll=poll):
        pass
    assert big.done and small.done
    assert eng.metrics.counters["staged_early"] >= 1
    e = _direct_energy(model, params, small)
    assert abs(small.energy - e) < 1e-4 * max(1.0, abs(e))


def test_deadline_holds_in_real_engine(small_model):
    """A request whose deadline lapsed while queued is rejected with the
    structured reason and never evaluated; co-queued live requests serve
    normally."""
    clock_t = {"t": 0.0}
    clock = lambda: clock_t["t"]  # noqa: E731
    model, params = small_model
    eng = EquivariantServeEngine(model, params, buckets=[(6, 1)],
                                 clock=clock)
    sched = Scheduler(eng, clock=clock)
    live = EquivariantRequest(*_mol(3, seed=3), rid=0)
    stale = EquivariantRequest(*_mol(3, seed=4), rid=1, deadline=0.5)
    sched.submit(live)
    sched.submit(stale)
    clock_t["t"] = 1.0               # stale expires while queued
    sched.drain()
    assert live.done and not live.rejected and live.energy is not None
    assert stale.rejected and stale.energy is None
    assert stale.reject_reason.startswith(REASON_DEADLINE)


def test_cfg_serve_buckets_knob(small_model):
    """EquivariantConfig.serve_buckets configures the ladder when the
    engine gets no explicit buckets argument (and the explicit argument
    wins over the config)."""
    model, params = small_model
    cfg = dataclasses.replace(model.cfg, serve_buckets=((4, 1), (8, 2)))
    model2 = MaceGaunt(cfg)
    eng = EquivariantServeEngine(model2, params)
    assert [p.spec.max_atoms for p in eng.pools] == [4, 8]
    assert eng.n_slots == 3
    eng2 = EquivariantServeEngine(model2, params, buckets=[(16, 1)])
    assert [p.spec.max_atoms for p in eng2.pools] == [16]


# ---------------------------------------------------------------------------
# the acceptance proof: per-bucket warmup on a warm cache = zero timing runs
# ---------------------------------------------------------------------------

_BUCKETED_CHILD = r"""
import dataclasses, json, os
import numpy as np
import jax
from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.models.equivariant import MaceGaunt
from repro.serve.engine import EquivariantRequest, EquivariantServeEngine
from repro.core import engine as ce

cfg = dataclasses.replace(gaunt_mace_ff, channels=4, n_layers=1, L=1,
                          L_edge=1, n_species=4, chain_tune="measure",
                          autotune_cache=os.environ["CACHE_PATH"])
model = MaceGaunt(cfg)
params = model.init(jax.random.PRNGKey(0))
# two buckets whose quantized chain batch_hints differ (4*4=16 vs 12*4=48
# rows), so per-bucket warmup seeds two DISTINCT measured chain keys
eng = EquivariantServeEngine(model, params, buckets=[(4, 1), (12, 1)],
                             warmup=True)
rng = np.random.default_rng(0)
reqs = [EquivariantRequest(species=rng.integers(0, 4, n),
                           pos=(rng.normal(size=(n, 3)) * 1.5)
                           .astype(np.float32), rid=i)
        for i, n in enumerate([3, 10])]          # one per bucket
out = eng.run(reqs)
assert all(r.done and not r.rejected for r in out)
assert all(p.steps_run > 0 for p in eng.pools)
g = ce.get_engine()
g.flush_autotune_cache()
print("RUNS=" + str(g.timing_runs))
print("PICKS=" + json.dumps(sorted((repr(k), repr(v))
                                   for k, v in g._measured.items())))
print("NKEYS=" + str(len(g._measured)))
print("SERVE_OK")
"""


def _subprocess_env() -> dict:
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(
        __file__))), "src")
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    return env


def test_per_bucket_warmup_zero_timing_runs_on_warm_cache(tmp_path):
    """ISSUE acceptance: a second process pointed at the populated autotune
    cache performs ZERO timing runs through the BUCKETED warmup (every
    bucket's chain keys answered from disk) + both buckets' first steps,
    selecting identically to the cold process."""
    env = _subprocess_env()
    env["CACHE_PATH"] = str(tmp_path / "bucketed_cache.json")
    out = []
    for _ in range(2):
        r = subprocess.run([sys.executable, "-c", _BUCKETED_CHILD],
                           capture_output=True, text=True, env=env,
                           timeout=900)
        assert "SERVE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
        vals = dict(ln.split("=", 1) for ln in r.stdout.splitlines()
                    if "=" in ln)
        out.append((int(vals["RUNS"]), vals["PICKS"], int(vals["NKEYS"])))
    (cold_runs, cold_picks, cold_n), (warm_runs, warm_picks, _) = out
    assert cold_runs > 0, "cold process should have measured something"
    assert cold_n >= 2, "per-bucket warmup should seed multiple keys"
    assert warm_runs == 0, \
        f"warm process ran {warm_runs} timing passes (cache not consulted)"
    assert warm_picks == cold_picks, "warm selections diverged from cold"
