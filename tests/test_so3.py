"""Unit + property tests for the exact SO(3) machinery."""
import math

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or clean skips when absent

from repro.core import so3
from repro.core.irreps import idx, num_coeffs


def test_wigner_3j_vs_sympy():
    from sympy.physics.wigner import wigner_3j as sp3j

    rng = np.random.default_rng(0)
    for _ in range(40):
        l1, l2 = rng.integers(0, 5, size=2)
        l3 = rng.integers(abs(l1 - l2), l1 + l2 + 1)
        m1 = rng.integers(-l1, l1 + 1)
        m2 = rng.integers(-l2, l2 + 1)
        m3 = -(m1 + m2)
        if abs(m3) > l3:
            continue
        ref = float(sp3j(int(l1), int(l2), int(l3), int(m1), int(m2), int(m3)))
        got = so3.wigner_3j(int(l1), int(l2), int(l3), int(m1), int(m2), int(m3))
        assert got == pytest.approx(ref, abs=1e-12)


def test_gaunt_complex_vs_sympy():
    from sympy.physics.wigner import gaunt as spg

    rng = np.random.default_rng(1)
    for _ in range(25):
        l1, l2, l3 = rng.integers(0, 4, size=3)
        m1 = rng.integers(-l1, l1 + 1)
        m2 = rng.integers(-l2, l2 + 1)
        m3 = -(m1 + m2)
        if abs(m3) > l3:
            continue
        ref = float(spg(int(l1), int(l2), int(l3), int(m1), int(m2), int(m3)))
        got = so3.gaunt_complex(int(l1), int(m1), int(l2), int(m2), int(l3), int(m3))
        assert got == pytest.approx(ref, abs=1e-12)


@given(st.integers(0, 4), st.integers(0, 4))
@settings(max_examples=15, deadline=None)
def test_cg_orthogonality(l1, l2):
    """sum_{m1,m2} C^{l,m} C^{l',m'} = delta_ll' delta_mm'."""
    for l3 in range(abs(l1 - l2), l1 + l2 + 1):
        for l3p in range(abs(l1 - l2), l1 + l2 + 1):
            for m3 in range(-l3, l3 + 1):
                for m3p in range(-l3p, l3p + 1):
                    s = 0.0
                    for m1 in range(-l1, l1 + 1):
                        m2, m2p = m3 - m1, m3p - m1
                        if abs(m2) <= l2 and abs(m2p) <= l2 and m2 == m2p:
                            s += so3.clebsch_gordan(l1, m1, l2, m2, l3, m3) * so3.clebsch_gordan(
                                l1, m1, l2, m2p, l3p, m3p
                            )
                    want = 1.0 if (l3 == l3p and m3 == m3p) else 0.0
                    assert s == pytest.approx(want, abs=1e-10)


def test_real_sh_orthonormal():
    L = 6
    xyz, w = so3.sphere_quadrature(2 * L)
    S = so3.real_sph_harm(L, xyz)  # [N, (L+1)^2]
    gram = np.einsum("n,ni,nj->ij", w, S, S)
    np.testing.assert_allclose(gram, np.eye(num_coeffs(L)), atol=1e-10)


def test_real_sh_vs_scipy():
    try:
        from scipy.special import sph_harm_y
    except ImportError:  # scipy < 1.15: old name, (m, l, azimuth, polar) order
        from scipy.special import sph_harm

        def sph_harm_y(l, m, theta, psi):
            return sph_harm(m, l, psi, theta)

    rng = np.random.default_rng(3)
    xyz = rng.normal(size=(10, 3))
    xyz /= np.linalg.norm(xyz, axis=-1, keepdims=True)
    theta = np.arccos(xyz[:, 2])
    psi = np.arctan2(xyz[:, 1], xyz[:, 0])
    S = so3.real_sph_harm(4, xyz)
    for l in range(5):
        for m in range(0, l + 1):
            Y = sph_harm_y(l, m, theta, psi)  # includes CS phase
            if m == 0:
                ref = Y.real
                np.testing.assert_allclose(S[:, idx(l, 0)], ref, atol=1e-12)
            else:
                ref_c = math.sqrt(2) * (-1) ** m * Y.real
                ref_s = math.sqrt(2) * (-1) ** m * Y.imag
                np.testing.assert_allclose(S[:, idx(l, m)], ref_c, atol=1e-12)
                np.testing.assert_allclose(S[:, idx(l, -m)], ref_s, atol=1e-12)


def test_real_sh_jax_matches_numpy():
    import jax.numpy as jnp

    rng = np.random.default_rng(4)
    xyz = rng.normal(size=(17, 3))
    xyz /= np.linalg.norm(xyz, axis=-1, keepdims=True)
    ref = so3.real_sph_harm(5, xyz)
    got = np.asarray(so3.real_sph_harm_jax(5, jnp.asarray(xyz)))
    np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)


def test_real_gaunt_tensor_vs_quadrature():
    L1, L2, L3 = 2, 2, 3
    G = so3.real_gaunt_tensor(L1, L2, L3)
    xyz, w = so3.sphere_quadrature(L1 + L2 + L3 + 1)
    S1 = so3.real_sph_harm(L1, xyz)
    S2 = so3.real_sph_harm(L2, xyz)
    S3 = so3.real_sph_harm(L3, xyz)
    ref = np.einsum("n,ni,nj,nk->ijk", w, S1, S2, S3)
    np.testing.assert_allclose(G, ref, atol=1e-10)


def test_real_gaunt_proportional_to_cg():
    """Eqn (3) of the paper: real-Gaunt block is a constant times the real CG
    block for each (l1,l2,l3)."""
    for (l1, l2, l3) in [(1, 1, 2), (2, 1, 1), (2, 2, 2), (3, 2, 1)]:
        if (l1 + l2 + l3) % 2:
            continue
        G = so3.real_gaunt_tensor(l1, l2, l3)[
            l1 * l1 : (l1 + 1) ** 2, l2 * l2 : (l2 + 1) ** 2, l3 * l3 : (l3 + 1) ** 2
        ]
        C = so3.real_clebsch_gordan_block(l1, l2, l3)
        denom = np.abs(C).max()
        mask = np.abs(C) > 1e-9 * denom
        ratios = G[mask] / C[mask]
        assert np.abs(ratios - ratios.flat[0]).max() < 1e-9


def test_real_cg_block_orthogonality():
    for (l1, l2, l3) in [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 2, 1), (2, 1, 3)]:
        C = so3.real_clebsch_gordan_block(l1, l2, l3)
        gram = np.einsum("ijk,ijl->kl", C, C)
        np.testing.assert_allclose(gram, np.eye(2 * l3 + 1), atol=1e-10)


@given(
    st.floats(-math.pi, math.pi),
    st.floats(0.01, math.pi - 0.01),
    st.floats(-math.pi, math.pi),
)
@settings(max_examples=20, deadline=None)
def test_wigner_D_convention(alpha, beta, gamma):
    """S^l(R r) == D^l_real(R) S^l(r) — the convention the whole stack uses."""
    rng = np.random.default_rng(int(abs(alpha * 1e4)) % 100)
    r = rng.normal(size=3)
    r /= np.linalg.norm(r)
    R = so3.rotation_matrix_zyz(alpha, beta, gamma)
    for l in range(4):
        S_r = so3.real_sph_harm(l, r)[l * l :]
        S_Rr = so3.real_sph_harm(l, R @ r)[l * l :]
        D = so3.wigner_D_real(l, alpha, beta, gamma)
        np.testing.assert_allclose(S_Rr, D @ S_r, atol=1e-9)


def test_wigner_D_is_orthogonal():
    D = so3.wigner_D_real(3, 0.3, 1.1, -0.7)
    np.testing.assert_allclose(D @ D.T, np.eye(7), atol=1e-10)


def test_euler_roundtrip():
    rng = np.random.default_rng(7)
    for _ in range(20):
        a, b, g = rng.uniform(-math.pi, math.pi), rng.uniform(0.05, math.pi - 0.05), rng.uniform(
            -math.pi, math.pi
        )
        R = so3.rotation_matrix_zyz(a, b, g)
        a2, b2, g2 = so3.euler_from_matrix_zyz(R)
        np.testing.assert_allclose(so3.rotation_matrix_zyz(a2, b2, g2), R, atol=1e-10)


def test_align_to_z():
    rng = np.random.default_rng(8)
    for _ in range(20):
        r = rng.normal(size=3)
        r /= np.linalg.norm(r)
        a, b, g = so3.align_to_z_angles(r)
        R = so3.rotation_matrix_zyz(a, b, g)
        np.testing.assert_allclose(R @ r, [0, 0, 1], atol=1e-10)
        # SH filter sparsity at the zenith: only m == 0 survives
        S = so3.real_sph_harm(4, R @ r)
        for l in range(5):
            for m in range(-l, l + 1):
                v = S[idx(l, m)]
                if m == 0:
                    assert abs(v - math.sqrt((2 * l + 1) / (4 * math.pi))) < 1e-9
                else:
                    assert abs(v) < 1e-9


def test_parity():
    """S^l(-r) = (-1)^l S^l(r)."""
    rng = np.random.default_rng(9)
    r = rng.normal(size=3)
    r /= np.linalg.norm(r)
    L = 5
    Sp = so3.real_sph_harm(L, r)
    Sm = so3.real_sph_harm(L, -r)
    for l in range(L + 1):
        sl = slice(l * l, (l + 1) ** 2)
        np.testing.assert_allclose(Sm[sl], (-1) ** l * Sp[sl], atol=1e-12)
