"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train-gradient step + one decode step on CPU; asserts shapes & finiteness.
Also validates decode-vs-forward consistency for every cache implementation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.configs import ALL_LM_ARCHS
from repro.models import build_model

BATCH, SEQ = 2, 32


def _batch_for(cfg, key, B=BATCH, S=SEQ):
    rng = np.random.default_rng(0)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        pos = np.stack([np.arange(S)] * 3, -1)[None].repeat(B, 0)
        b["positions3"] = jnp.asarray(pos, jnp.int32)
    if cfg.family == "encdec":
        b["source_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.max_source_len, cfg.d_model)), jnp.float32
        )
    return b


@pytest.mark.parametrize("arch", ALL_LM_ARCHS)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 0)

    logits, aux = jax.jit(m.forward)(params, batch)
    assert logits.shape == (BATCH, SEQ, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    loss, metrics = m.loss(params, batch)
    g = jax.jit(jax.grad(lambda p: m.loss(p, batch)[0]))(params)
    gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(g)))
    assert bool(jnp.isfinite(loss)) and bool(jnp.isfinite(gn)), arch
    assert float(gn) > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ALL_LM_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step after prefill must reproduce the forward logits at the next
    position (same math through the cache path)."""
    # lossless MoE capacity: token-dropping legitimately differs between the
    # joint forward batch and the decode batch, so remove drops for this check
    cfg = get_config(arch).reduced(capacity_factor=8.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg, 1)
    S = SEQ

    logits_all, _ = jax.jit(m.forward)(params, batch)
    last, cache = jax.jit(lambda p, b: m.prefill(p, b, S + 8))(params, batch)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(logits_all[:, -1]), atol=2e-2, rtol=2e-2
    )

    # feed token S (from the batch extended by one) — compare against forward
    # of the full S+1 sequence
    ext = jnp.concatenate(
        [batch["tokens"], batch["tokens"][:, :1]], axis=1
    )  # arbitrary next token
    b2 = dict(batch, tokens=ext)
    if cfg.family == "vlm":
        pos = np.stack([np.arange(S + 1)] * 3, -1)[None].repeat(BATCH, 0)
        b2["positions3"] = jnp.asarray(pos, jnp.int32)
    logits_ext, _ = jax.jit(m.forward)(params, b2)
    pos = jnp.full((BATCH,), S, jnp.int32)
    step_logits, _ = jax.jit(m.decode_step)(params, cache, ext[:, -1:], pos)
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(logits_ext[:, -1]),
        atol=3e-2, rtol=3e-2,
    )


def test_moe_dispatch_matches_dense_reference():
    from repro.models.moe import moe_apply, moe_dense_reference, moe_init

    key = jax.random.PRNGKey(2)
    d, E, k, ff = 32, 8, 2, 64
    p = moe_init(key, d, E, ff, n_shared=0, act="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, d))
    y, aux = moe_apply(p, x, E, k, cf=8.0, act="swiglu")  # huge capacity: no drops
    ref = moe_dense_reference(p, x, E, k, act="swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_bounded():
    from repro.models.moe import moe_apply, moe_init

    key = jax.random.PRNGKey(4)
    d, E, k, ff = 16, 4, 2, 32
    p = moe_init(key, d, E, ff, n_shared=0, act="swiglu")
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 64, d))
    y, _ = moe_apply(p, x, E, k, cf=0.5, act="swiglu")  # forced drops
    assert bool(jnp.all(jnp.isfinite(y)))


def test_full_config_param_counts():
    """Full (non-reduced) configs must hit their published scale (eval_shape,
    no allocation)."""
    from repro.models import count_params

    expected = {
        "dbrx-132b": (125e9, 140e9),
        "qwen2-vl-72b": (65e9, 80e9),
        "qwen1.5-32b": (30e9, 36e9),  # assignment spec kv=40 (MHA) > real model's GQA
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "qwen2-0.5b": (0.4e9, 0.65e9),
        "stablelm-3b": (2.5e9, 3.8e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "zamba2-2.7b": (2.2e9, 3.5e9),
        "whisper-base": (0.05e9, 0.12e9),
        "rwkv6-3b": (2.5e9, 3.5e9),
    }
    for arch, (lo, hi) in expected.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9},{hi/1e9}]"
