"""SH <-> Fourier round-trip precision (DESIGN.md §6 acceptance).

Exact float64 round trips (the conversion tensors are analytic), bounded
float32/complex64 error up to L=8 for the dense, packed, and half (Hermitian
real-input) forms, and chained-product (Fourier-resident) vs looped
(per-product round trip) numerical identity including per-degree weights and
gradients.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constants, engine
from repro.core import fourier as fx
from repro.core import rep as rep_mod
from repro.core.cg import gaunt_einsum_reference
from repro.core.gaunt import expand_degree_weights, fourier_to_sh, sh_to_fourier
from repro.core.irreps import num_coeffs

LS = [1, 2, 3, 5, 8]


def _rand(shape, seed, dtype=np.float64):
    return np.random.default_rng(seed).normal(size=shape).astype(dtype)


# --------------------------------------------------------------------------
# float64 exactness (numpy: the conversion tensors at full precision)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("L", LS)
def test_roundtrip_exact_float64_dense(L):
    x = _rand((4, num_coeffs(L)), L)
    y = constants.y_dense(L, "complex128")
    z = constants.z_dense(L, L, "complex128")
    F = np.einsum("...i,iuv->...uv", x, y)
    back = np.einsum("...uv,uvk->...k", F, z).real
    np.testing.assert_allclose(back, x, atol=1e-12)


@pytest.mark.parametrize("L", LS)
def test_roundtrip_exact_float64_half(L):
    x = _rand((4, num_coeffs(L)), L + 10)
    yh = constants.y_half(L, "complex128")
    zh = constants.z_half(L, L, "complex128")
    Fh = np.einsum("...i,iuv->...uv", x, yh)
    back = np.einsum("...uv,uvk->...k", Fh, zh).real
    np.testing.assert_allclose(back, x, atol=1e-12)
    # the half grid really is the v >= 0 slab of the full (Hermitian) grid
    F = np.einsum("...i,iuv->...uv", x, constants.y_dense(L, "complex128"))
    np.testing.assert_allclose(Fh, F[..., L:], atol=1e-12)
    np.testing.assert_allclose(F[..., ::-1, ::-1], np.conj(F), atol=1e-12)


@pytest.mark.parametrize("L", LS)
def test_roundtrip_exact_float64_truncating_projection(L):
    """Projecting a bandlimited grid to FEWER degrees slices exactly."""
    x = _rand((3, num_coeffs(L)), L + 20)
    Lout = max(0, L - 1)
    y = constants.y_dense(L, "complex128")
    z = constants.z_dense(L, Lout, "complex128")
    F = np.einsum("...i,iuv->...uv", x, y)
    back = np.einsum("...uv,uvk->...k", F, z).real
    np.testing.assert_allclose(back, x[..., : num_coeffs(Lout)], atol=1e-12)


# --------------------------------------------------------------------------
# float32 / complex64 bounded error (jax, all conversion forms)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("conversion", ["dense", "packed", "half"])
@pytest.mark.parametrize("L", LS)
def test_roundtrip_float32_bounded(conversion, L):
    x = jnp.asarray(_rand((8, num_coeffs(L)), L + 30), jnp.float32)
    F = sh_to_fourier(x, L, conversion, jnp.complex64)
    back = fourier_to_sh(F, L, L, conversion, jnp.float32)
    scale = float(jnp.abs(x).max())
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=2e-5 * max(1.0, scale))


@pytest.mark.parametrize("L", LS)
def test_grid_ops_roundtrip(L):
    """resize up then down and pack/unpack are lossless."""
    x = jnp.asarray(_rand((2, num_coeffs(L)), L + 40), jnp.float32)
    F = sh_to_fourier(x, L, "dense", jnp.complex64)
    up = fx.grid_resize(F, L, L + 3)
    down = fx.grid_resize(up, L + 3, L)
    np.testing.assert_allclose(np.asarray(down), np.asarray(F), atol=0)
    Fh = fx.pack_hermitian(F, L)
    full = fx.unpack_hermitian(Fh, L)
    np.testing.assert_allclose(np.asarray(full), np.asarray(F), atol=1e-6)
    Fh_up = fx.grid_resize_half(Fh, L, L + 2)
    np.testing.assert_allclose(
        np.asarray(fx.grid_resize_half(Fh_up, L + 2, L)), np.asarray(Fh), atol=0)


@pytest.mark.parametrize("L", LS)
def test_rep_roundtrip_and_forms(L):
    x = jnp.asarray(_rand((3, num_coeffs(L)), L + 50), jnp.float32)
    r = rep_mod.Rep.from_sh(x, L)
    for conversion in ("dense", "half"):
        back = r.to_fourier(conversion).to_sh()
        assert back.basis == "sh" and back.L == L
        np.testing.assert_allclose(np.asarray(back.data), np.asarray(x), atol=2e-5)
    # form change on the resident side is lossless
    rf = r.to_fourier("dense")
    np.testing.assert_allclose(
        np.asarray(rf.with_form("half").with_form("dense").data),
        np.asarray(rf.data), atol=1e-6)


# --------------------------------------------------------------------------
# chained (Fourier-resident) vs looped (per-product round trip) identity
# --------------------------------------------------------------------------


def _looped_fold(xs, Ls, Lout, weights=None):
    """The per-product path: every step converts in and projects out."""
    acc, La = xs[0], Ls[0]
    if weights is not None and weights[0] is not None:
        acc = acc * expand_degree_weights(weights[0], La).astype(acc.dtype)
    for i, (x, L) in enumerate(zip(xs[1:], Ls[1:]), start=1):
        if weights is not None and weights[i] is not None:
            x = x * expand_degree_weights(weights[i], L).astype(x.dtype)
        last = i == len(Ls) - 1
        Lt = Lout if last else La + L
        p = engine.plan(La, L, Lt, backend="fft", requires_grad=True)
        acc = p.apply(acc, x)
        La += L
    return acc


@pytest.mark.parametrize("conversion", ["dense", "half"])
def test_chain_matches_looped(conversion):
    Ls = (2, 1, 2, 3)
    Lout = 3
    xs = [jnp.asarray(_rand((6, num_coeffs(L)), 60 + i), jnp.float32)
          for i, L in enumerate(Ls)]
    cp = engine.plan_chain(Ls, Lout, conversion=conversion)
    got = cp.apply(xs)
    ref = _looped_fold(xs, Ls, Lout)
    scale = max(1.0, float(jnp.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-4 * scale)


@pytest.mark.parametrize("conversion", ["dense", "half"])
def test_chain_matches_looped_with_weights_and_grad(conversion):
    L, nu, Lout = 2, 3, 2
    x = jnp.asarray(_rand((4, num_coeffs(L)), 70), jnp.float32)
    ws = [jnp.asarray(_rand((4, L + 1), 71 + i), jnp.float32) for i in range(nu)]

    def chained(x):
        cp = engine.plan_chain((L,) * nu, Lout, conversion=conversion)
        return cp.apply([x] * nu, weights=ws)

    def looped(x):
        return _looped_fold([x] * nu, (L,) * nu, Lout, weights=ws)

    got, ref = chained(x), looped(x)
    scale = max(1.0, float(jnp.abs(ref).max()))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=5e-4 * scale)
    g1 = jax.grad(lambda a: jnp.sum(chained(a) ** 2))(x)
    g2 = jax.grad(lambda a: jnp.sum(looped(a) ** 2))(x)
    gscale = max(1.0, float(jnp.abs(g2).max()))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=5e-4 * gscale)


def test_chain_jit_matches_eager():
    Ls = (2, 2, 2)
    xs = [jnp.asarray(_rand((5, num_coeffs(2)), 80 + i), jnp.float32)
          for i in range(3)]
    cp = engine.plan_chain(Ls, 2)
    eager = cp.apply(xs)
    jitted = jax.jit(lambda *a: cp.apply(list(a)))(*xs)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager), atol=1e-5)


def test_chain_oracle_reference():
    """Chained product equals the exact dense-Gaunt fold, not just the
    looped spectral path."""
    Ls = (2, 2, 2)
    xs = [jnp.asarray(_rand((4, num_coeffs(2)), 90 + i), jnp.float32)
          for i in range(3)]
    got = engine.plan_chain(Ls, 2).apply(xs)
    acc = gaunt_einsum_reference(xs[0], xs[1], 2, 2)
    acc = gaunt_einsum_reference(acc, xs[2], 4, 2, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), atol=2e-3)
