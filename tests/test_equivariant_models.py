"""E(3) symmetry + trainability of the paper-side models."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gaunt_ff import EquivariantConfig
from repro.core import so3
from repro.core.irreps import num_coeffs
from repro.data import lj_dataset, nbody_dataset
from repro.models.equivariant import MaceGaunt, SegnnNBody, SelfmixLayer

CFG_MACE = EquivariantConfig(name="t", kind="mace", L=1, L_edge=1, channels=8,
                             n_layers=1, n_species=4, nu=2, hidden=16, n_radial=4)
CFG_SEGNN = EquivariantConfig(name="t", kind="segnn", L=1, L_edge=1, channels=8,
                              n_layers=2, hidden=16, n_radial=4)


def _rot():
    return 0.5, 1.1, -0.8


def test_mace_energy_invariance():
    m = MaceGaunt(CFG_MACE)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    species = jnp.asarray(rng.integers(0, 4, 6), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(6, 3)) * 1.5, jnp.float32)
    e1 = m.energy(params, species, pos)
    assert bool(jnp.isfinite(e1)), "energy is not finite"
    a, b, g = _rot()
    R = jnp.asarray(so3.rotation_matrix_zyz(a, b, g), jnp.float32)
    e2 = m.energy(params, species, pos @ R.T)
    np.testing.assert_allclose(float(e1), float(e2), atol=1e-3, rtol=1e-4)
    # translation invariance
    e3 = m.energy(params, species, pos + jnp.asarray([1.0, -2.0, 0.5]))
    np.testing.assert_allclose(float(e1), float(e3), atol=1e-3, rtol=1e-4)


def test_mace_forces_equivariance():
    m = MaceGaunt(CFG_MACE)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    species = jnp.asarray(rng.integers(0, 4, 5), jnp.int32)
    pos = jnp.asarray(rng.normal(size=(5, 3)) * 1.5, jnp.float32)
    _, f1 = m.energy_forces(params, species, pos)
    assert bool(jnp.all(jnp.isfinite(f1)))
    a, b, g = _rot()
    R = jnp.asarray(so3.rotation_matrix_zyz(a, b, g), jnp.float32)
    _, f2 = m.energy_forces(params, species, pos @ R.T)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1) @ np.asarray(R).T,
                               atol=2e-3, rtol=1e-3)


def test_mace_trains_on_lj():
    m = MaceGaunt(CFG_MACE)
    params = m.init(jax.random.PRNGKey(2))
    data = lj_dataset(8, n_atoms=6, n_species=4, seed=0)
    batch = {k: jnp.asarray(v) for k, v in data.items()}

    loss_fn = jax.jit(m.loss)
    grad_fn = jax.jit(jax.grad(m.loss))
    l0 = float(loss_fn(params, batch))
    for _ in range(8):
        g = grad_fn(params, batch)
        params = jax.tree.map(lambda p, gg: p - 3e-3 * gg, params, g)
    l1 = float(loss_fn(params, batch))
    assert np.isfinite(l0) and np.isfinite(l1)
    assert l1 < l0, (l0, l1)


def test_segnn_equivariance():
    m = SegnnNBody(CFG_SEGNN)
    params = m.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    charge = jnp.asarray(rng.choice([-1.0, 1.0], 5), jnp.float32)
    pos = jnp.asarray(rng.normal(size=(5, 3)), jnp.float32)
    vel = jnp.asarray(rng.normal(size=(5, 3)), jnp.float32)
    out1 = m.forward(params, charge, pos, vel)
    assert bool(jnp.all(jnp.isfinite(out1)))
    a, b, g = _rot()
    R = jnp.asarray(so3.rotation_matrix_zyz(a, b, g), jnp.float32)
    out2 = m.forward(params, charge, pos @ R.T, vel @ R.T)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out1) @ np.asarray(R).T,
                               atol=2e-3, rtol=1e-3)


@pytest.mark.parametrize("impl", ["gaunt", "cg"])
def test_segnn_trains_nbody(impl):
    cfg = dataclasses.replace(CFG_SEGNN, tp_impl=impl)
    m = SegnnNBody(cfg)
    params = m.init(jax.random.PRNGKey(4))
    data = nbody_dataset(6, horizon=200, seed=1)
    batch = {k: jnp.asarray(v) for k, v in data.items()}
    loss_fn = jax.jit(m.loss)
    grad_fn = jax.jit(jax.grad(m.loss))
    l0 = float(loss_fn(params, batch))
    for _ in range(6):
        g = grad_fn(params, batch)
        params = jax.tree.map(lambda p, gg: p - 1e-2 * gg, params, g)
    l1 = float(loss_fn(params, batch))
    assert l1 < l0, (impl, l0, l1)


@pytest.mark.parametrize("impl", ["gaunt", "gaunt_fused", "cg"])
def test_selfmix_layer_equivariance(impl):
    L, C = 2, 4
    layer = SelfmixLayer(L=L, channels=C, tp_impl=impl)
    params = layer.init(jax.random.PRNGKey(5))
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(3, C, num_coeffs(L))), jnp.float32)
    a, b, g = _rot()
    D = jnp.asarray(so3.wigner_D_real_packed(L, a, b, g), jnp.float32)
    y1 = layer(params, x)
    y2 = layer(params, jnp.einsum("ij,ncj->nci", D, x))
    np.testing.assert_allclose(
        np.asarray(jnp.einsum("ij,ncj->nci", D, y1)), np.asarray(y2),
        atol=3e-3, rtol=1e-3)


def test_selfmix_gaunt_equals_fused():
    L, C = 2, 4
    a = SelfmixLayer(L=L, channels=C, tp_impl="gaunt")
    b = SelfmixLayer(L=L, channels=C, tp_impl="gaunt_fused")
    params = a.init(jax.random.PRNGKey(6))
    x = jnp.asarray(np.random.default_rng(6).normal(size=(3, C, num_coeffs(L))), jnp.float32)
    np.testing.assert_allclose(np.asarray(a(params, x)), np.asarray(b(params, x)),
                               atol=2e-4, rtol=2e-4)


def test_no_duplicate_random_init_leaves():
    """PRNG-key hygiene regression (PR 4): MaceGaunt.init reused k4 for
    mb_mix AND gate (with ks[3] never consumed), SegnnNBody.init reused k3
    for mix AND self_mix and the radial key for gate — bitwise-correlated
    parameters at init.  Every random leaf must now be unique; constant
    leaves (ones-initialized weights) are exempt by construction."""
    models = [
        MaceGaunt(dataclasses.replace(CFG_MACE, n_layers=2)),
        SegnnNBody(dataclasses.replace(CFG_SEGNN, n_layers=2)),
        SelfmixLayer(L=2, channels=4),
    ]
    for i, m in enumerate(models):
        params = m.init(jax.random.PRNGKey(i))
        rand = [np.asarray(leaf) for leaf in jax.tree.leaves(params)
                if np.unique(np.asarray(leaf)).size > 1]
        assert rand, f"{type(m).__name__}: no random leaves found"
        blobs = [leaf.tobytes() for leaf in rand]
        assert len(blobs) == len(set(blobs)), (
            f"{type(m).__name__}: two random init leaves are bitwise-identical")
