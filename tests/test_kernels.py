"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracles,
swept over shapes and dtypes as required."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cg import gaunt_einsum_reference
from repro.core.irreps import num_coeffs
from repro.kernels import ref
from repro.kernels.gaunt_fused import gaunt_fused_matrices, gaunt_fused_pallas
from repro.kernels.mamba2 import mamba2_ssd_chunked, mamba2_ssd_pallas
from repro.kernels.wkv6 import wkv6_chunked, wkv6_pallas


def _rand(shape, seed=0, dtype=jnp.float32):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape), dtype=dtype)


# ---------------------------------------------------------------- gaunt fused


@pytest.mark.parametrize("L1,L2,Lout", [(1, 1, 2), (2, 2, 4), (3, 2, 3), (4, 4, 8)])
@pytest.mark.parametrize("B", [1, 7, 300])
def test_gaunt_fused_vs_oracle(L1, L2, Lout, B):
    x1 = _rand((B, num_coeffs(L1)), 1)
    x2 = _rand((B, num_coeffs(L2)), 2)
    got = gaunt_fused_pallas(x1, x2, L1, L2, Lout, block_b=128, interpret=True)
    want = gaunt_einsum_reference(x1, x2, L1, L2, Lout)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gaunt_fused_dtypes(dtype):
    """Pairwise kernel at f32/bf16 storage — bounds from the shared
    per-precision tiers (repro.testing.tol_for)."""
    from repro.testing import assert_close

    L1 = L2 = 2
    x1 = _rand((64, num_coeffs(L1)), 3, dtype)
    x2 = _rand((64, num_coeffs(L2)), 4, dtype)
    got = gaunt_fused_pallas(x1, x2, L1, L2, 4, block_b=64, interpret=True)
    want = gaunt_einsum_reference(x1.astype(jnp.float32), x2.astype(jnp.float32), L1, L2, 4)
    assert_close(np.asarray(got, dtype=np.float32), np.asarray(want),
                 dtype=dtype, tier="identity")


def test_gaunt_fused_matches_unfused_ref():
    L1, L2, Lout = 3, 3, 6
    T1, T2, P = (jnp.asarray(a) for a in gaunt_fused_matrices(L1, L2, Lout))
    x1 = _rand((32, num_coeffs(L1)), 5)
    x2 = _rand((32, num_coeffs(L2)), 6)
    got = gaunt_fused_pallas(x1, x2, L1, L2, Lout, block_b=32, interpret=True)
    want = ref.gaunt_fused_ref(x1, x2, T1, T2, P)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_gaunt_fused_leading_dims():
    L1 = L2 = 2
    x1 = _rand((2, 3, num_coeffs(L1)), 7)
    x2 = _rand((2, 3, num_coeffs(L2)), 8)
    out = gaunt_fused_pallas(x1, x2, L1, L2, None, block_b=8, interpret=True)
    assert out.shape == (2, 3, num_coeffs(4))


# ---------------------------------------------------------------- wkv6


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (48, 16)])
@pytest.mark.parametrize("K", [8, 16])
def test_wkv6_chunked_vs_naive(T, chunk, K):
    B, H, V = 2, 3, K
    rng = np.random.default_rng(10)
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, V)), dtype=jnp.float32)
    w = jnp.asarray(rng.uniform(0.2, 0.999, size=(B, T, H, K)), dtype=jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.3, dtype=jnp.float32)
    want = ref.wkv6_ref(r, k, v, w, u)
    got = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_wkv6_pallas_vs_naive():
    B, T, H, K = 2, 32, 2, 8
    rng = np.random.default_rng(11)
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype=jnp.float32)
    w = jnp.asarray(rng.uniform(0.2, 0.999, size=(B, T, H, K)), dtype=jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.3, dtype=jnp.float32)
    want = ref.wkv6_ref(r, k, v, w, u)
    got = wkv6_pallas(r, k, v, w, u, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_wkv6_extreme_decay_stable():
    """Very strong decay must not overflow/NaN (stability of masked exps)."""
    B, T, H, K = 1, 64, 1, 8
    rng = np.random.default_rng(12)
    r = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), dtype=jnp.float32)
    w = jnp.full((B, T, H, K), 1e-6, dtype=jnp.float32)  # near-total forget
    u = jnp.zeros((H, K), dtype=jnp.float32)
    got = wkv6_chunked(r, k, v, w, u, chunk=64)
    assert bool(jnp.all(jnp.isfinite(got)))
    want = ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


# ---------------------------------------------------------------- mamba2 ssd


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 32)])
def test_mamba2_chunked_vs_naive(T, chunk):
    Bt, H, P, G, N = 2, 4, 8, 2, 16
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(Bt, T, H, P)), dtype=jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(Bt, T, H)), dtype=jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), dtype=jnp.float32)
    B = jnp.asarray(rng.normal(size=(Bt, T, G, N)), dtype=jnp.float32)
    C = jnp.asarray(rng.normal(size=(Bt, T, G, N)), dtype=jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), dtype=jnp.float32)
    want = ref.mamba2_ssd_ref(x, dt, A, B, C, D)
    got = mamba2_ssd_chunked(x, dt, A, B, C, D, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


def test_mamba2_pallas_vs_naive():
    Bt, T, H, P, G, N = 1, 32, 2, 8, 1, 8
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.normal(size=(Bt, T, H, P)), dtype=jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(Bt, T, H)), dtype=jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), dtype=jnp.float32)
    B = jnp.asarray(rng.normal(size=(Bt, T, G, N)), dtype=jnp.float32)
    C = jnp.asarray(rng.normal(size=(Bt, T, G, N)), dtype=jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), dtype=jnp.float32)
    want = ref.mamba2_ssd_ref(x, dt, A, B, C, D)
    got = mamba2_ssd_pallas(x, dt, A, B, C, D, chunk=8, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


# ------------------------------------------------------- channel-mix gaunt


def test_gaunt_channel_mix_matches_pairwise_oracle():
    """Fused-domain channel mixing == explicit sum over channel-pair TPs."""
    from repro.kernels.ops import gaunt_tp_channel_mix

    L1, L2, Lout, C1, C2, E = 2, 2, 3, 3, 2, 4
    rng = np.random.default_rng(40)
    x1 = jnp.asarray(rng.normal(size=(5, C1, num_coeffs(L1))), jnp.float32)
    x2 = jnp.asarray(rng.normal(size=(5, C2, num_coeffs(L2))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(C1, C2, E)), jnp.float32)
    got = gaunt_tp_channel_mix(x1, x2, w, L1, L2, Lout)
    ref = jnp.zeros((5, E, num_coeffs(Lout)))
    for c1 in range(C1):
        for c2 in range(C2):
            tp = gaunt_einsum_reference(x1[:, c1], x2[:, c2], L1, L2, Lout)
            ref = ref + w[c1, c2][None, :, None] * tp[:, None, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=3e-4,
                               rtol=3e-4)


def test_gaunt_channel_mix_equivariance():
    from repro.core import so3
    from repro.kernels.ops import gaunt_tp_channel_mix

    L, C, E = 2, 3, 3
    rng = np.random.default_rng(41)
    x = jnp.asarray(rng.normal(size=(C, num_coeffs(L))), jnp.float32)
    w = jnp.asarray(rng.normal(size=(C, C, E)), jnp.float32)
    D_in = jnp.asarray(so3.wigner_D_real_packed(L, 0.7, 0.9, -1.1), jnp.float32)
    D_out = jnp.asarray(so3.wigner_D_real_packed(2 * L, 0.7, 0.9, -1.1), jnp.float32)
    y = gaunt_tp_channel_mix(x[None], x[None], w, L, L)[0]
    y_rot = gaunt_tp_channel_mix((x @ D_in.T)[None], (x @ D_in.T)[None], w, L, L)[0]
    np.testing.assert_allclose(np.asarray(y @ D_out.T), np.asarray(y_rot),
                               atol=3e-4, rtol=3e-4)
