"""Train a ~100M-param LM (reduced qwen2 family) on the synthetic Markov
corpus for a few hundred steps with the production train loop.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --dim 512
(defaults are CPU-sized; --dim 768 --layers 12 gives ~100M params)
"""
import argparse

import jax
import numpy as np

from repro.config import TrainConfig, get_config
from repro.data import LMTokenPipeline
from repro.models import build_model
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/lm_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(
        d_model=args.dim, n_layers=args.layers, n_heads=max(4, args.dim // 64),
        n_kv_heads=max(2, args.dim // 128), head_dim=64, d_ff=args.dim * 4,
        vocab=args.vocab, attn_chunk=args.seq, max_seq=args.seq * 2,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M")

    pipe = LMTokenPipeline(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    tcfg = TrainConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                       checkpoint_every=100, log_every=10)
    state, hist = train_loop(model.loss, params, pipe, tcfg, ckpt_dir=args.ckpt,
                             hooks={"log": lambda m: print(
                                 f"step {m['step']:4d}  loss {m['loss']:.4f}  "
                                 f"ce {m['ce']:.4f}")})
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
