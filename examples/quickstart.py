"""Quickstart: the Gaunt Tensor Product as a drop-in equivariant primitive.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cg import cg_full_tensor_product, gaunt_einsum_reference
from repro.core.conv import EquivariantConv
from repro.core.gaunt import GauntTensorProduct
from repro.core.irreps import num_coeffs
from repro.core.manybody import manybody_selfmix
from repro.core.so3 import wigner_D_real_packed
from repro.kernels.ops import gaunt_tp_fused_xla


def main():
    L = 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, num_coeffs(L))), jnp.float32)
    y = jnp.asarray(rng.normal(size=(8, num_coeffs(L))), jnp.float32)

    # 1) full Gaunt tensor product, three equivalent realizations
    tp = GauntTensorProduct(L, L)           # paper's FFT pipeline
    out_fft = tp(x, y)
    out_fused = gaunt_tp_fused_xla(x, y, L, L)   # TPU-native fused form
    out_ref = gaunt_einsum_reference(x, y, L, L)  # dense oracle
    print("max |fft - ref|   =", float(jnp.abs(out_fft - out_ref).max()))
    print("max |fused - ref| =", float(jnp.abs(out_fused - out_ref).max()))

    # 2) O(3) equivariance
    D_in = jnp.asarray(wigner_D_real_packed(L, 0.3, 1.1, -0.7), jnp.float32)
    D_out = jnp.asarray(wigner_D_real_packed(2 * L, 0.3, 1.1, -0.7), jnp.float32)
    lhs = out_ref @ D_out.T
    rhs = gaunt_einsum_reference(x @ D_in.T, y @ D_in.T, L, L)
    print("equivariance error =", float(jnp.abs(lhs - rhs).max()))

    # 3) equivariant convolution with the eSCN-sparsity fast path
    r = jnp.asarray(rng.normal(size=(8, 3)), jnp.float32)
    conv = EquivariantConv(L, L, L, method="escn")
    print("escn conv out:", conv(x, r).shape)

    # 4) many-body products (MACE-style B_nu features)
    B3 = manybody_selfmix(x, L, nu=3, Lout=L)
    print("3-body selfmix out:", B3.shape)

    # 5) the speedup story (jit-compiled timings on this machine)
    cg = jax.jit(lambda a, b: cg_full_tensor_product(a, b, L, L, L))
    fast = jax.jit(lambda a, b: gaunt_tp_fused_xla(a, b, L, L, L))
    for f, name in ((cg, "CG (e3nn-style)"), (fast, "Gaunt fused")):
        jax.block_until_ready(f(x, y))
        t0 = time.perf_counter()
        for _ in range(20):
            jax.block_until_ready(f(x, y))
        print(f"{name:>18}: {(time.perf_counter() - t0) / 20 * 1e6:8.1f} us/call")


if __name__ == "__main__":
    main()
