"""End-to-end driver (the paper's kind): train a Gaunt-MACE force field on
synthetic Lennard-Jones clusters for a few hundred steps, with the full
training substrate (AdamW + cosine, checkpointing, resume).

    PYTHONPATH=src python examples/train_force_field.py --steps 300
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig
from repro.configs.gaunt_ff import gaunt_mace_ff
from repro.data import lj_dataset
from repro.models.equivariant import MaceGaunt
from repro.train import train_loop


class LJBatches:
    """Resumable batch iterator over a fixed synthetic dataset."""

    def __init__(self, n=128, batch=16, seed=0):
        self.data = lj_dataset(n, n_atoms=8, n_species=4, seed=seed)
        self.n, self.batch, self.step = n, batch, 0

    def state(self):
        return {"step": self.step}

    def restore(self, s):
        self.step = int(s["step"])

    def next_batch(self):
        rng = np.random.default_rng((1234, self.step))
        idx = rng.choice(self.n, self.batch, replace=False)
        self.step += 1
        return {k: v[idx] for k, v in self.data.items()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt", default="/tmp/gaunt_mace_ckpt")
    ap.add_argument("--channels", type=int, default=16)
    ap.add_argument("--L", type=int, default=2)
    args = ap.parse_args()

    cfg = dataclasses.replace(gaunt_mace_ff, channels=args.channels, L=args.L,
                              L_edge=2, n_layers=1, nu=2)
    model = MaceGaunt(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}  params={n_params:,}")

    tcfg = TrainConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps,
                       checkpoint_every=100, log_every=10, grad_clip=10.0)

    def loss_fn(p, batch):
        loss = model.loss(p, batch)
        return loss, {"mse": loss}

    state, hist = train_loop(loss_fn, params, LJBatches(), tcfg, ckpt_dir=args.ckpt,
                             hooks={"log": lambda m: print(
                                 f"step {m['step']:4d}  loss {m['loss']:.4f}")})
    print(f"final loss: {hist[-1]['loss']:.4f}  (start {hist[0]['loss']:.4f})")
    # quick validation: energy invariance of the trained model
    from repro.core.so3 import rotation_matrix_zyz

    d = lj_dataset(1, n_atoms=8, n_species=4, seed=99)
    R = jnp.asarray(rotation_matrix_zyz(0.5, 1.0, -0.3), jnp.float32)
    s, pos = jnp.asarray(d["species"][0]), jnp.asarray(d["pos"][0])
    e1 = model.energy(state.params, s, pos)
    e2 = model.energy(state.params, s, pos @ R.T)
    print(f"rotation invariance: E={float(e1):.5f} vs {float(e2):.5f}")


if __name__ == "__main__":
    main()
