"""Serve a small LM with batched requests through the continuous-batching
engine (any of the 10 archs; reduced config on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b --requests 6
"""
import argparse
import time

import jax

from repro.config import get_config
from repro.models import build_model
from repro.serve import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, n_slots=args.slots, max_len=128)

    reqs = [
        Request(prompt=[(7 * i + j) % cfg.vocab for j in range(4 + i % 3)],
                max_new_tokens=args.max_new, temperature=args.temperature, rid=i)
        for i in range(args.requests)
    ]
    t0 = time.time()
    engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt={r.prompt} -> {r.output}")
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, {args.slots} slots, arch={args.arch})")


if __name__ == "__main__":
    main()
